//! Ablation: the KSUB / accumulator trade-off of paper §3.3.
//!
//! * larger KSUB → fewer tasks → less per-task overhead, but A/B panels
//!   must fit the 32 KB local stores (KSUB = 128 does NOT fit — shown);
//! * the "Accumulator" (commands 0/1/2) vs sending results back on every
//!   task: the or-ratio collapse the paper describes.

use parallella_blas::epiphany::kernel::KernelGeometry;
use parallella_blas::epiphany::timing::CalibratedModel;
use parallella_blas::epiphany::Chip;
use parallella_blas::host::projection::{project_ukr_call, ProjectionParams};
use parallella_blas::util::tables::{secs, Table};

fn main() {
    let model = CalibratedModel::default();
    let k_total = 4096;

    let mut t = Table::new(
        "Ablation — KSUB sweep at M=192, N=256, K=4096 (same-process kernel)",
        &["KSUB", "fits 32KB?", "tasks", "input s (ir share)", "coproc s", "total s"],
    );
    for ksub in [16usize, 32, 64, 128] {
        let geom = KernelGeometry { m: 192, n: 256, ksub, nsub: 4 };
        let fits = Chip::new(model.clone(), geom).is_ok();
        if !fits {
            t.row(&[
                ksub.to_string(),
                "NO (Fig-3 map overflows)".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        let mut p = ProjectionParams::kernel_same_process(k_total);
        p.ksub = ksub;
        let proj = project_ukr_call(&model, &p);
        t.row(&[
            ksub.to_string(),
            "yes".into(),
            (k_total / ksub).to_string(),
            format!("{} ({:.1}%)", secs(proj.input_s), 100.0 * proj.input_s / proj.total_s),
            secs(proj.coproc_s),
            secs(proj.total_s),
        ]);
    }
    t.print();

    // Accumulator vs send-back-every-task: or-ratio collapse.
    let mut t2 = Table::new(
        "Ablation — accumulator (commands 0/1/2) vs send-back every task",
        &["K", "accumulator total s", "send-every-task total s", "penalty"],
    );
    for k in [256usize, 1024, 4096] {
        let p = ProjectionParams::kernel_same_process(k);
        let acc = project_ukr_call(&model, &p);
        // Send-every-task: each task additionally writes the result out and
        // the host reads + sums it (the slow §5.2 read per task).
        let tasks = (k / 64) as f64;
        let out_bytes = (192 * 256 * 4) as f64;
        let per_task_extra = out_bytes / model.w_chip_write
            + out_bytes / model.w_host_read
            + 192.0 * 256.0 / (model.host_stream_gflops * 1e9);
        let send = acc.total_s + (tasks - 1.0) * per_task_extra;
        t2.row(&[
            k.to_string(),
            secs(acc.total_s),
            secs(send),
            format!("{:.2}x", send / acc.total_s),
        ]);
    }
    t2.print();
    println!(
        "conclusion: KSUB=64 is the largest panel fitting the Fig-3 map; the accumulator\n\
         protocol turns the per-task result write-back + slow host read into a one-time cost\n\
         (or → 0 as K grows), which is the paper's 'An Accumulator' design."
    );
}
