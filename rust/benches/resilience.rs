//! Resilience bench: what one dead chip costs a serving pool.
//!
//! A 4-chip coordinator serves the same pipelined sgemm stream through
//! three phases — all chips healthy, one chip killed mid-stream (every
//! service call on it fails; the batcher wounds it and requeues), and
//! after a probe re-admits the chip. The interesting numbers are the
//! degraded-phase throughput (3/4 of the pool should deliver roughly
//! 3/4 of the rate, not zero) and the rescue count.
//!
//! Machine-readable copy lands in `BENCH_resilience.json`.

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{Request, ServerConfig};
use parallella_blas::linalg::Mat;
use parallella_blas::util::bench::write_bench_json;
use parallella_blas::util::tables::Table;
use std::collections::VecDeque;
use std::time::Instant;

/// Drive `reqs` copies of `req` through a depth-8 sliding window and
/// return the achieved request rate. Every response is verified to be a
/// result, not an error — resilience means zero lost tickets.
fn stream(cli: &mut BlasClient, req: &Request, reqs: usize) -> f64 {
    let depth = 8;
    let t0 = Instant::now();
    let mut window = VecDeque::new();
    for _ in 0..reqs {
        while window.len() >= depth {
            let p = window.pop_front().unwrap();
            p.wait().unwrap().into_f32().unwrap();
        }
        window.push_back(cli.submit(req).unwrap());
    }
    while let Some(p) = window.pop_front() {
        p.wait().unwrap().into_f32().unwrap();
    }
    reqs as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let reqs = if quick { 16 } else { 48 };
    let chips = 4usize;
    let srv = BlasServer::start(ServerConfig { chips, ..Default::default() })
        .expect("server boots");
    let blas = srv.blas_handle();
    let mut cli = BlasClient::connect_v2(srv.addr()).expect("v2 session");

    let (m, n, k) = (96usize, 64usize, 128usize);
    let a = Mat::<f32>::randn(m, k, 1);
    let b = Mat::<f32>::randn(k, n, 2);
    let req = Request::sgemm(
        Trans::N,
        Trans::N,
        m,
        n,
        k,
        1.0,
        0.0,
        a.as_slice().to_vec(),
        b.as_slice().to_vec(),
        vec![0.0; m * n],
    );

    let healthy_rps = stream(&mut cli, &req, reqs);

    // Kill chip 1 mid-service: sticky faults, every call on it errors.
    let requeued_before = srv.metrics.requeued();
    blas.pool().chip(1).fail_next_calls(usize::MAX);
    let wounded_rps = stream(&mut cli, &req, reqs);
    let rescued = srv.metrics.requeued() - requeued_before;
    let healthy_left = blas.pool().healthy_chips().len();

    // Probe recovery: clear the fault, ping the chip back into rotation.
    blas.pool().chip(1).clear_faults();
    blas.pool().probe(1).expect("probe re-admits the chip");
    let recovered_rps = stream(&mut cli, &req, reqs);

    let mut t = Table::new(
        "Coordinator resilience (4 chips, m=96 n=64 k=128, depth-8 stream)",
        &["phase", "healthy chips", "req/s"],
    );
    t.row(&["all healthy".into(), chips.to_string(), format!("{healthy_rps:.1}")]);
    t.row(&["one chip dead".into(), healthy_left.to_string(), format!("{wounded_rps:.1}")]);
    t.row(&[
        "after probe".into(),
        blas.pool().healthy_chips().len().to_string(),
        format!("{recovered_rps:.1}"),
    ]);
    t.print();
    println!(
        "degraded/healthy rate: {:.2}x with {rescued} job(s) rescued off the dead chip\n\
         (every ticket still answered — the cost of a chip death is throughput, not loss)",
        wounded_rps / healthy_rps
    );

    let json = format!(
        "{{\"bench\":\"resilience\",\"quick\":{quick},\"chips\":{chips},\
         \"healthy_req_s\":{healthy_rps:.3},\"wounded_req_s\":{wounded_rps:.3},\
         \"recovered_req_s\":{recovered_rps:.3},\"rescued\":{rescued},\
         \"table\":{}}}",
        t.to_json()
    );
    let path = write_bench_json("resilience", &json).expect("write bench json");
    println!("wrote {}", path.display());
}
