//! Batched-gemm bench: what the `GemmBatch` opcode buys over one frame
//! per item. The same set of small square sgemms goes over a live
//! server twice — first as `count` single `Gemm` frames, then as one
//! `GemmBatch` frame whose items fan across the chip pool — on pools of
//! 1 and 4 chips, across an items × item-size matrix.
//!
//! Written machine-readable to `BENCH_batch.json`.

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{GemmWire, Request, ServerConfig};
use parallella_blas::linalg::Mat;
use parallella_blas::util::bench::write_bench_json;
use parallella_blas::util::tables::Table;
use std::time::Instant;

/// `count` independent s×s×s f32 items (C starts zeroed, β = 0).
fn items(count: usize, s: usize) -> Vec<GemmWire> {
    (0..count)
        .map(|i| {
            let seed = 900 + i as u64 * 3;
            GemmWire::f32(
                Trans::N,
                Trans::N,
                s,
                s,
                s,
                1.0,
                0.0,
                Mat::<f32>::randn(s, s, seed).as_slice().to_vec(),
                Mat::<f32>::randn(s, s, seed + 1).as_slice().to_vec(),
                vec![0.0f32; s * s],
            )
        })
        .collect()
}

/// Wall seconds for (one frame per item, one batch frame) against a
/// fresh `chips`-pool server; the two paths see identical payloads.
fn run(chips: usize, count: usize, s: usize) -> (f64, f64) {
    let srv = BlasServer::start(ServerConfig { chips, ..Default::default() }).unwrap();
    let mut cli = BlasClient::connect(srv.addr()).unwrap();
    let its = items(count, s);
    // One untimed call warms the service threads and code paths.
    cli.call(&Request::Gemm(its[0].clone())).unwrap().into_f32().unwrap();
    let t0 = Instant::now();
    for g in &its {
        cli.call(&Request::Gemm(g.clone())).unwrap().into_f32().unwrap();
    }
    let singles = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    cli.call(&Request::gemm_batch(its.clone())).unwrap().into_f32().unwrap();
    let batch = t0.elapsed().as_secs_f64();
    (singles, batch)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let counts: &[usize] = if quick { &[8] } else { &[16, 64] };
    let sizes: &[usize] = if quick { &[16] } else { &[16, 32] };

    let mut t = Table::new(
        "Batched small gemm over the wire (f32 square items, per-frame vs one GemmBatch)",
        &["chips", "items", "size", "singles s", "batch s", "speedup", "batch items/s"],
    );
    let mut cells = Vec::new();
    for &chips in &[1usize, 4] {
        for &count in counts {
            for &s in sizes {
                let (singles, batch) = run(chips, count, s);
                let speedup = singles / batch.max(1e-12);
                let rate = count as f64 / batch.max(1e-12);
                t.row(&[
                    chips.to_string(),
                    count.to_string(),
                    format!("{s}x{s}x{s}"),
                    format!("{singles:.6}"),
                    format!("{batch:.6}"),
                    format!("{speedup:.2}x"),
                    format!("{rate:.0}"),
                ]);
                cells.push(format!(
                    "{{\"chips\":{chips},\"items\":{count},\"size\":{s},\
                     \"singles_s\":{singles:.6},\"batch_s\":{batch:.6},\
                     \"speedup\":{speedup:.3},\"batch_items_per_s\":{rate:.1}}}"
                ));
            }
        }
    }
    t.print();
    println!(
        "one GemmBatch frame amortizes framing + dispatch over every item \
         and fans the items across the pool's least-loaded healthy chips\n"
    );

    let json = format!(
        "{{\"bench\":\"batch\",\"quick\":{quick},\"table\":{},\"cells\":[{}]}}",
        t.to_json(),
        cells.join(",")
    );
    let path = write_bench_json("batch", &json).expect("write bench json");
    println!("wrote {}", path.display());
}
