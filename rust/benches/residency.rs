//! Operand-residency bench: what the packed-A panel cache and the wire
//! buffer pool buy on a serving-style workload (one weight matrix, many
//! requests).
//!
//! Two sections, both written machine-readable to `BENCH_residency.json`:
//!
//! * repeated same-A sgemm with the cache off vs on — seconds per pass
//!   (the hit speedup) and caller-thread allocations per pass (the
//!   pack-side allocations a verified hit avoids);
//! * frame decode with the shared wire pool disabled vs enabled —
//!   allocations per decoded frame body.
//!
//! Allocations are counted by a thread-local counting `GlobalAlloc`, so
//! service-thread noise never pollutes the caller-side numbers.

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::FrameAccumulator;
use parallella_blas::linalg::Mat;
use parallella_blas::mem::BufferPool;
use parallella_blas::platform::Platform;
use parallella_blas::util::bench::write_bench_json;
use parallella_blas::util::tables::Table;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;
use std::time::Instant;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Passes every call to the system allocator, counting allocations per
/// thread on the way.
struct CountingAlloc;

// SAFETY: delegates verbatim to `System`; the counter bump cannot
// allocate (const-initialised thread-local `Cell`).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

/// Repeated same-A sgemm against one platform. Returns (seconds/pass,
/// caller-thread allocations/pass, panel hits, panel misses) over the
/// timed passes (one untimed warm pass populates the cache).
fn run_gemm(cache_bytes: usize, passes: usize) -> (f64, f64, u64, u64) {
    let plat = Platform::builder().panel_cache_bytes(cache_bytes).build().unwrap();
    let (m, n, k) = (192usize, 64usize, 256usize);
    let a = Mat::<f32>::randn(m, k, 1);
    let b = Mat::<f32>::randn(k, n, 2);
    let mut c = Mat::<f32>::zeros(m, n);
    plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
    let a0 = allocs();
    let t0 = Instant::now();
    for _ in 0..passes {
        plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64() / passes as f64;
    let da = (allocs() - a0) as f64 / passes as f64;
    let (hits, misses) = match plat.blas().panel_cache() {
        Some(cache) => {
            let s = cache.stats();
            (s.hits, s.misses)
        }
        None => (0, 0),
    };
    (dt, da, hits, misses)
}

/// Decode `frames` 16 KiB frames through a [`FrameAccumulator`] whose
/// wire pool retains `retained` free buffers (0 = pooling off: every
/// frame body is a fresh allocation). Returns allocations per frame on
/// the decoding thread.
fn run_frames(retained: usize, frames: usize) -> f64 {
    let pool = Arc::new(BufferPool::<u8>::new(retained));
    let mut acc = FrameAccumulator::with_pool(1 << 20, pool);
    let body = vec![7u8; 16 * 1024];
    let mut frame = (body.len() as u32).to_le_bytes().to_vec();
    frame.extend_from_slice(&body);
    acc.extend(&frame);
    drop(acc.try_frame().unwrap()); // warm: seed the pool / the buffers
    let a0 = allocs();
    for _ in 0..frames {
        acc.extend(&frame);
        let b = acc.try_frame().unwrap().expect("one whole frame buffered");
        std::hint::black_box(b.len());
    }
    (allocs() - a0) as f64 / frames as f64
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let (passes, frames) = if quick { (2, 64) } else { (8, 512) };

    let mut t = Table::new(
        "Panel cache: repeated same-A sgemm (192x64x256, simulator)",
        &["cache", "s/pass", "allocs/pass", "panel hits", "panel misses"],
    );
    let (t_off, a_off, _, _) = run_gemm(0, passes);
    t.row(&[
        "off".into(),
        format!("{t_off:.6}"),
        format!("{a_off:.1}"),
        "-".into(),
        "-".into(),
    ]);
    let (t_on, a_on, hits, misses) = run_gemm(64 << 20, passes);
    t.row(&[
        "on".into(),
        format!("{t_on:.6}"),
        format!("{a_on:.1}"),
        hits.to_string(),
        misses.to_string(),
    ]);
    t.print();
    let speedup = t_off / t_on;
    println!(
        "cache-hit speedup: {speedup:.2}x; caller-thread allocations/request \
         {a_off:.1} -> {a_on:.1}\n(the hit serves the resident packed panel as a \
         shared Arc: no pack, no pack-side allocation)\n"
    );

    let mut ft = Table::new(
        "Wire pool: 16 KiB frame decode",
        &["pool", "allocs/frame"],
    );
    let f_off = run_frames(0, frames);
    ft.row(&["off (retain 0)".into(), format!("{f_off:.2}")]);
    let f_on = run_frames(8, frames);
    ft.row(&["on (retain 8)".into(), format!("{f_on:.2}")]);
    ft.print();
    println!(
        "pooled frame bodies recycle the previous frame's capacity \
         ({f_off:.2} -> {f_on:.2} allocs/frame)\n"
    );

    let json = format!(
        "{{\"bench\":\"residency\",\"quick\":{quick},\"gemm\":{},\
         \"frame_decode\":{},\"hit_speedup\":{speedup:.3},\
         \"allocs_per_request_off\":{a_off:.1},\"allocs_per_request_on\":{a_on:.1},\
         \"panel_hits\":{hits},\"panel_misses\":{misses},\
         \"frame_allocs_unpooled\":{f_off:.2},\"frame_allocs_pooled\":{f_on:.2}}}",
        t.to_json(),
        ft.to_json(),
    );
    let path = write_bench_json("residency", &json).expect("write bench json");
    println!("wrote {}", path.display());
}
