//! Serving-style throughput/latency bench of the L3 coordinator — the
//! measurement the paper's single-workgroup architecture implies but never
//! reports: what happens when many BLAS clients share the chip(s).
//!
//! Workload generator: open-loop clients issuing sgemm requests with a
//! shared weight matrix (coalescible) or per-request matrices
//! (uncoalescible), across request-size classes — and, for the sharded
//! pool, the same serving-style stream against 1 vs 4 chips. Clients
//! spread chip affinity with wire shard hints, so each chip's batcher
//! coalesces its own queue.
//!
//! The wire-v2 section measures the connections × in-flight-depth
//! matrix: sliding-window pipelined sessions against the same server,
//! quantifying what correlation-id pipelining buys over the
//! one-request-per-round-trip v1 wire.
//!
//! All sections are also written machine-readable to
//! `BENCH_coordinator.json` at the repo root.

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{Request, ServerConfig};
use parallella_blas::linalg::{Mat, XorShiftRng};
use parallella_blas::util::bench::write_bench_json;
use parallella_blas::util::tables::Table;
use std::collections::VecDeque;
use std::time::Instant;

struct Workload {
    name: &'static str,
    clients: usize,
    reqs_per_client: usize,
    n_cols: usize,
    shared_weights: bool,
    chips: usize,
}

fn run(w: &Workload) -> (f64, f64, f64, u64) {
    let srv = BlasServer::start(ServerConfig { chips: w.chips, ..Default::default() })
        .expect("server boots");
    let addr = srv.addr();
    let (m, k) = (192usize, 256usize);
    let shared = Mat::<f32>::randn(m, k, 1).as_slice().to_vec();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..w.clients {
        let shared = shared.clone();
        let (n_cols, reqs, shared_w, chips) =
            (w.n_cols, w.reqs_per_client, w.shared_weights, w.chips);
        handles.push(std::thread::spawn(move || {
            let mut cli = BlasClient::connect(addr).unwrap();
            let mut rng = XorShiftRng::new(c as u64 + 17);
            for i in 0..reqs {
                let a = if shared_w {
                    shared.clone()
                } else {
                    Mat::<f32>::randn(m, k, c as u64 * 1000 + i as u64).as_slice().to_vec()
                };
                let b: Vec<f32> = (0..k * n_cols).map(|_| rng.next_unit() as f32).collect();
                let req = Request::sgemm(
                    Trans::N,
                    Trans::N,
                    m,
                    n_cols,
                    k,
                    1.0,
                    0.0,
                    a,
                    b,
                    vec![0.0; m * n_cols],
                )
                .with_shard_hint(c % chips);
                let resp = cli.call(&req).unwrap();
                assert_eq!(resp.into_f32().unwrap().len(), m * n_cols);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let total = (w.clients * w.reqs_per_client) as f64;
    (
        total / elapsed,
        srv.metrics.latency_quantile(0.5),
        srv.metrics.latency_quantile(0.99),
        srv.metrics.requests(),
    )
}

/// One cell of the pipelining matrix: `connections` v2 sessions, each
/// keeping `depth` requests in flight with a sliding window (shared
/// weight matrix, so the batcher can coalesce whatever lands together).
fn run_pipelined(connections: usize, depth: usize, reqs_per_conn: usize) -> (f64, f64, f64) {
    let srv = BlasServer::start(ServerConfig::default()).expect("server boots");
    let addr = srv.addr();
    let (m, n, k) = (96usize, 64usize, 128usize);
    let shared = Mat::<f32>::randn(m, k, 1).as_slice().to_vec();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..connections {
        let shared = shared.clone();
        handles.push(std::thread::spawn(move || {
            let mut cli = BlasClient::connect_v2(addr).unwrap();
            let mut rng = XorShiftRng::new(c as u64 + 41);
            let mut window = VecDeque::new();
            for _ in 0..reqs_per_conn {
                while window.len() >= depth {
                    let p = window.pop_front().unwrap();
                    assert_eq!(p.wait().unwrap().into_f32().unwrap().len(), m * n);
                }
                let b: Vec<f32> = (0..k * n).map(|_| rng.next_unit() as f32).collect();
                let req = Request::sgemm(
                    Trans::N,
                    Trans::N,
                    m,
                    n,
                    k,
                    1.0,
                    0.0,
                    shared.clone(),
                    b,
                    vec![0.0; m * n],
                );
                window.push_back(cli.submit(&req).unwrap());
            }
            while let Some(p) = window.pop_front() {
                assert_eq!(p.wait().unwrap().into_f32().unwrap().len(), m * n);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let total = (connections * reqs_per_conn) as f64;
    (total / elapsed, srv.metrics.latency_quantile(0.5), srv.metrics.latency_quantile(0.99))
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").ok().as_deref() == Some("1");
    let scale = if quick { 1 } else { 2 };
    let workloads = [
        Workload {
            name: "shared-A small",
            clients: 4,
            reqs_per_client: 8 * scale,
            n_cols: 32,
            shared_weights: true,
            chips: 1,
        },
        Workload {
            name: "shared-A large",
            clients: 4,
            reqs_per_client: 4 * scale,
            n_cols: 256,
            shared_weights: true,
            chips: 1,
        },
        Workload {
            name: "unique-A small",
            clients: 4,
            reqs_per_client: 8 * scale,
            n_cols: 32,
            shared_weights: false,
            chips: 1,
        },
        Workload {
            name: "single client ",
            clients: 1,
            reqs_per_client: 16 * scale,
            n_cols: 64,
            shared_weights: true,
            chips: 1,
        },
    ];
    let mut t = Table::new(
        "L3 coordinator throughput (m=192, k=256 tile requests)",
        &["workload", "req/s", "p50 s", "p99 s", "executed gemms"],
    );
    for w in &workloads {
        let (rps, p50, p99, execs) = run(w);
        t.row(&[
            w.name.into(),
            format!("{rps:.1}"),
            format!("{p50:.4}"),
            format!("{p99:.4}"),
            execs.to_string(),
        ]);
    }
    t.print();
    println!(
        "shared-A rows execute fewer gemms than requests (batch coalescing across one\n\
         Epiphany workgroup); unique-A cannot coalesce and pays per-request IPC.\n"
    );

    // ChipPool scaling: the same serving-style stream (one weight matrix,
    // many B panels, clients fanned across chips by shard hints) against
    // a 1-chip and a 4-chip pool.
    let mut scaling = Table::new(
        "ChipPool scaling (serving-style: shared A, 8 clients, n=64)",
        &["chips", "req/s", "p50 s", "p99 s", "executed gemms"],
    );
    let mut rates = Vec::new();
    for chips in [1usize, 4] {
        let w = Workload {
            name: "pool",
            clients: 8,
            reqs_per_client: 6 * scale,
            n_cols: 64,
            shared_weights: true,
            chips,
        };
        let (rps, p50, p99, execs) = run(&w);
        rates.push(rps);
        scaling.row(&[
            chips.to_string(),
            format!("{rps:.1}"),
            format!("{p50:.4}"),
            format!("{p99:.4}"),
            execs.to_string(),
        ]);
    }
    scaling.print();
    println!(
        "ChipPool(4) vs ChipPool(1) speedup: {:.2}x (each chip owns its own HH-RAM window,\n\
         service loop and batcher queue; level-3 streams drain concurrently)",
        rates[1] / rates[0]
    );

    // Wire-v2 pipelining: connections × in-flight-depth matrix.
    let mut pipeline = Table::new(
        "Wire-v2 pipelining (m=96, n=64, k=128, shared A)",
        &["connections", "depth", "req/s", "p50 s", "p99 s"],
    );
    let reqs_per_conn = 8 * scale;
    let mut cells = Vec::new();
    for connections in [1usize, 4] {
        for depth in [1usize, 8] {
            let (rps, p50, p99) = run_pipelined(connections, depth, reqs_per_conn);
            pipeline.row(&[
                connections.to_string(),
                depth.to_string(),
                format!("{rps:.1}"),
                format!("{p50:.4}"),
                format!("{p99:.4}"),
            ]);
            cells.push((connections, depth, rps, p50, p99));
        }
    }
    pipeline.print();
    let rate_of = |conns: usize, depth: usize| {
        cells.iter().find(|c| c.0 == conns && c.1 == depth).map(|c| c.2).unwrap_or(0.0)
    };
    let depth_speedup = rate_of(1, 8) / rate_of(1, 1);
    println!(
        "depth-8 vs depth-1 on one connection: {depth_speedup:.2}x (the window keeps the\n\
         batcher fed and coalescing instead of idling a full RTT between requests)\n"
    );

    // Machine-readable artifact for the perf trajectory.
    let matrix_json: Vec<String> = cells
        .iter()
        .map(|(c, d, rps, p50, p99)| {
            format!(
                "{{\"connections\":{c},\"depth\":{d},\"req_s\":{rps:.3},\
                 \"p50_s\":{p50:.6},\"p99_s\":{p99:.6}}}"
            )
        })
        .collect();
    let json = format!(
        "{{\"bench\":\"coordinator_throughput\",\"quick\":{quick},\
         \"workloads\":{},\"pool_scaling\":{},\"pipelining\":[{}],\
         \"depth8_over_depth1\":{depth_speedup:.3}}}",
        t.to_json(),
        scaling.to_json(),
        matrix_json.join(",")
    );
    let path = write_bench_json("coordinator", &json).expect("write bench json");
    println!("wrote {}", path.display());
}
