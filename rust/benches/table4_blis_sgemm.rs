//! Bench target regenerating the paper's Table 4. Set BENCH_FULL=1 to run
//! the executed part at the paper's sizes (default: reduced sizes; the
//! projected columns are always at paper scale).
use parallella_blas::experiments::{table4, ExperimentScale};

fn main() {
    let scale = ExperimentScale::from_env();
    let t = table4(scale).expect("table reproduction runs");
    println!("{}", t.rendered);
    for c in &t.checks {
        println!(
            "check {:<22} paper={:<12.6} ours={:<12.6} ratio={:.3}",
            c.name,
            c.paper,
            c.ours,
            c.ratio()
        );
    }
    let path = parallella_blas::util::bench::write_bench_json("table4", &t.to_json("table4"))
        .expect("write bench json");
    println!("wrote {}", path.display());
}
