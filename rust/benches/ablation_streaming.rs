//! Ablation: the paper's §5 future-work solutions, measured.
//!
//! * **output-streaming** (§5.2, Fig 9): shrink RES2, send partials back
//!   per task — enables bigger m/n (better ir) but pays the slow host
//!   HC-RAM read per task. The paper implemented this first and abandoned
//!   it; the projection shows why.
//! * **b-streaming** (§5.1): keep B in HC-RAM, fetch `NSUB·CORES`-column
//!   slivers on demand — frees local space for a taller A panel.
//!
//! Functional check: the simulator executes the send-every-task protocol
//! (command 3 per panel + host accumulation) and must agree bit-wise in
//! result class with the accumulator run.

use parallella_blas::epiphany::kernel::{Command, KernelGeometry, TaskInputs};
use parallella_blas::epiphany::memory::LocalMemory;
use parallella_blas::epiphany::timing::CalibratedModel;
use parallella_blas::epiphany::Chip;
use parallella_blas::host::projection::{project_ukr_call, ProjectionParams};
use parallella_blas::linalg::{max_scaled_err, Mat};
use parallella_blas::util::tables::{secs, Table};

/// Fig-9-style map: RES2 shrunk to one m × NSUB block, B partially local.
fn output_streaming_fits(m: usize, ksub: usize, nsub: usize, b_sliver_cols: usize) -> bool {
    let mut lm = LocalMemory::new();
    let cores = parallella_blas::epiphany::CORES;
    lm.alloc_f32("A", m * (ksub / cores)).is_ok()
        && lm.alloc_f32("B sliver", (ksub / cores) * b_sliver_cols).is_ok()
        && lm.alloc_f32("RES1", m * nsub).is_ok()
        && lm.alloc_f32("RES2 (shrunk)", m * nsub).is_ok()
}

fn main() {
    let model = CalibratedModel::default();
    let k = 4096usize;

    let mut t = Table::new(
        "Ablation — accumulator vs output-streaming vs b-streaming (K=4096)",
        &["variant", "geometry", "fits?", "projected s", "GFLOPS"],
    );
    let flops = |m: usize, n: usize| 2.0 * m as f64 * n as f64 * k as f64;

    // Baseline accumulator (paper production config).
    let base = project_ukr_call(&model, &ProjectionParams::kernel_same_process(k));
    t.row(&[
        "accumulator (paper)".into(),
        "m=192 n=256 KSUB=64".into(),
        "yes".into(),
        secs(base.total_s),
        format!("{:.3}", flops(192, 256) / base.total_s / 1e9),
    ]);

    // Output-streaming: m=384 (taller panel halves the relative b upload),
    // results stream back per task through the slow host read.
    {
        let (m, n, ksub, nsub) = (384usize, 256usize, 32usize, 4usize);
        let fits = output_streaming_fits(m, ksub, nsub, nsub * parallella_blas::epiphany::CORES);
        let mut p = ProjectionParams::kernel_same_process(k);
        p.m = m;
        p.ksub = ksub;
        let acc = project_ukr_call(&model, &p);
        let tasks = (k / ksub) as f64;
        let out_bytes = (m * n * 4) as f64;
        let per_task_extra = out_bytes / model.w_chip_write
            + out_bytes / model.w_host_read
            + (m * n) as f64 / (model.host_stream_gflops * 1e9);
        let total = acc.total_s + (tasks - 1.0) * per_task_extra;
        t.row(&[
            "output-streaming (§5.2)".into(),
            format!("m={m} n={n} KSUB={ksub}"),
            if fits { "yes (Fig-9 map)" } else { "NO" }.into(),
            secs(total),
            format!("{:.3}", flops(m, n) / total / 1e9),
        ]);
    }

    // b-streaming: B slivers on demand double the A budget → m=384 with
    // the accumulator still on (RES2 = m × n/16 must fit: needs n=128).
    {
        let (m, n, ksub) = (384usize, 128usize, 32usize);
        let mut lm = LocalMemory::new();
        let cores = parallella_blas::epiphany::CORES;
        let fits = lm.alloc_f32("A", m * (ksub / cores)).is_ok()
            && lm.alloc_f32("B sliver", (ksub / cores) * 4 * cores).is_ok()
            && lm.alloc_f32("RES1", m * 4).is_ok()
            && lm.alloc_f32("RES2", m * (n / cores)).is_ok();
        let mut p = ProjectionParams::kernel_same_process(k);
        p.m = m;
        p.n = n;
        p.ksub = ksub;
        let proj = project_ukr_call(&model, &p);
        t.row(&[
            "b-streaming (§5.1)".into(),
            format!("m={m} n={n} KSUB={ksub}"),
            if fits { "yes" } else { "NO" }.into(),
            secs(proj.total_s),
            format!("{:.3}", flops(m, n) / proj.total_s / 1e9),
        ]);
    }
    t.print();

    // Functional agreement: send-every-task == accumulator numerics.
    let geom = KernelGeometry::paper();
    let k_small = 4 * geom.ksub;
    let a = Mat::<f32>::randn(geom.m, k_small, 7);
    let b = Mat::<f32>::randn(k_small, geom.n, 8);
    let b_rm = |b: &Mat<f32>, r0: usize| {
        let mut v = vec![0.0f32; geom.ksub * geom.n];
        for l in 0..geom.ksub {
            for j in 0..geom.n {
                v[l * geom.n + j] = b.get(r0 + l, j);
            }
        }
        v
    };

    // Accumulator run.
    let mut chip = Chip::new(model.clone(), geom).unwrap();
    for t_i in 0..k_small / geom.ksub {
        let a_p = a.view().sub(0, t_i * geom.ksub, geom.m, geom.ksub).to_mat();
        let cmd = match (t_i == 0, t_i == k_small / geom.ksub - 1) {
            (true, _) => Command::ClearAccumulate,
            (_, true) => Command::AccumulateSend,
            _ => Command::Accumulate,
        };
        chip.upload_and_run(
            TaskInputs { a_panel: a_p.as_slice(), b_panel: &b_rm(&b, t_i * geom.ksub) },
            cmd,
            t_i & 1,
        )
        .unwrap();
    }
    let mut acc_out = vec![0.0f32; geom.m * geom.n];
    chip.host_read_out(&mut acc_out);

    // Send-every-task run with host-side accumulation.
    let mut chip2 = Chip::new(model, geom).unwrap();
    let mut stream_out = vec![0.0f32; geom.m * geom.n];
    for t_i in 0..k_small / geom.ksub {
        let a_p = a.view().sub(0, t_i * geom.ksub, geom.m, geom.ksub).to_mat();
        chip2
            .upload_and_run(
                TaskInputs { a_panel: a_p.as_slice(), b_panel: &b_rm(&b, t_i * geom.ksub) },
                Command::ClearSend,
                t_i & 1,
            )
            .unwrap();
        let mut partial = vec![0.0f32; geom.m * geom.n];
        chip2.host_read_out(&mut partial);
        for (o, p) in stream_out.iter_mut().zip(&partial) {
            *o += p;
        }
    }
    let acc_m = Mat::from_col_major(geom.m, geom.n, &acc_out);
    let str_m = Mat::from_col_major(geom.m, geom.n, &stream_out);
    let err = max_scaled_err(str_m.view(), acc_m.view());
    println!(
        "functional agreement (accumulator vs send-every-task + host sum): \
         max scaled err {err:.2e}"
    );
    assert!(err < 1e-6, "protocols disagree: {err}");
    println!(
        "conclusion: output-streaming's taller panels cannot compensate the per-task slow\n\
         HC-RAM host read — matching the paper's experience (§5.2); b-streaming only pays\n\
         off once the slow-read penalty is fixed in the FPGA/e-link."
    );
}
